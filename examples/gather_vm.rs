//! Irregular transfers through virtual memory: a scatter/gather job
//! resolved by the [`ScatterGather`] mid-end and translated by the
//! [`Mmu`]'s IOTLB + page-table walker, verified against the software
//! oracle — then a demand-paging run where the destination pages start
//! unmapped and a [`Supervisor`] fault handler maps each faulting page
//! and replays the job.
//!
//! Writes a small JSON report (verify flag, TLB hit rate, page-fault
//! count, cold/warm cycles). `IDMA_BENCH_SMOKE=1` shrinks the sizes for
//! CI.
//!
//! Run: `cargo run --release --example gather_vm [report.json]`
//!
//! [`ScatterGather`]: idma::midend::ScatterGather
//! [`Mmu`]: idma::vm::Mmu
//! [`Supervisor`]: idma::resilience::Supervisor

use idma::mem::SparseMemory;
use idma::midend::{NdJob, ScatterGather, SgConfig, SgMode};
use idma::protocol::ProtocolKind;
use idma::resilience::{RetryPolicy, Supervisor};
use idma::sim::bench::scaled;
use idma::sim::XorShift64;
use idma::system::IdmaSystem;
use idma::systems::cheshire::Cheshire;
use idma::telemetry::{shared, Recorder};
use idma::transfer::{NdTransfer, Transfer1D};
use idma::workloads::GatherPattern;

const SRC_VA: u64 = 0x0010_0000;
const DST_VA: u64 = 0x0800_0000;
const SRC_PA: u64 = 0x8000_0000;
const DST_PA: u64 = 0x9000_0000;
const IDX_PA: u64 = 0x6000_0000;
const PAGE: u64 = 4096;

fn run_gather(sys: &mut IdmaSystem, p: &GatherPattern, job: u64) -> u64 {
    let sg = sys.engine.mids[0]
        .as_any_mut()
        .expect("scatter_gather is programmable")
        .downcast_mut::<ScatterGather>()
        .expect("mid 0 is the scatter/gather stage");
    sg.program(
        job,
        SgConfig {
            index_base: IDX_PA,
            index_count: p.count(),
            index_width: 8,
            mode: SgMode::Gather,
        },
    );
    let t = Transfer1D::copy(0, SRC_VA, DST_VA, p.elem_len, ProtocolKind::Axi4);
    let j = NdJob::new(job, NdTransfer::d1(t));
    while !sys.submit(j.clone()) {
        sys.step();
    }
    let start = sys.now();
    sys.run_until_idle() - start
}

fn main() {
    let out = std::env::args().nth(1).unwrap_or_else(|| "gather_vm.json".to_string());

    // --- Part 1: verified gather, cold vs warm IOTLB -------------------
    let p = GatherPattern::random(scaled(512, 128) as usize, 512, false, 0x9E1, 64);
    let (mut sys, mut pt) = Cheshire::default().virtual_system();
    let src_span = (p.max_index() + 1) * p.elem_len;
    let mut src = vec![0u8; src_span as usize];
    XorShift64::new(0xFACE).fill(&mut src);
    sys.mems[0].data.write(SRC_PA, &src);
    p.write_indices(&mut sys.mems[0].data, IDX_PA, 8);
    for off in (0..src_span.div_ceil(PAGE) * PAGE).step_by(PAGE as usize) {
        pt.map(&mut sys.mems[0].data, SRC_VA + off, SRC_PA + off);
    }
    for off in (0..p.total_bytes().div_ceil(PAGE) * PAGE).step_by(PAGE as usize) {
        pt.map(&mut sys.mems[0].data, DST_VA + off, DST_PA + off);
    }
    let rec = shared(Recorder::new());
    sys.attach_sink(rec.clone());
    let cold_cycles = run_gather(&mut sys, &p, 1);
    let warm_cycles = run_gather(&mut sys, &p, 2);

    let got = sys.mems[0].data.read_vec(DST_PA, p.total_bytes() as usize);
    let want = {
        let mut m = SparseMemory::new();
        m.write(SRC_PA, &src);
        p.oracle_gather(&m, SRC_PA)
    };
    let verify = got == want;
    assert!(verify, "gather must match the software oracle");
    assert!(cold_cycles > warm_cycles, "cold TLB ({cold_cycles}) vs warm ({warm_cycles})");
    let s = rec.borrow().summary();
    println!(
        "gather: {} x {} B verified; cold {cold_cycles} / warm {warm_cycles} cycles",
        p.count(),
        p.elem_len
    );
    println!(
        "IOTLB: {} hits / {} misses (hit rate {:.3}), {} PTW beats",
        s.tlb_hits,
        s.tlb_misses,
        s.tlb_hit_rate(),
        s.ptw_beats
    );

    // --- Part 2: demand paging through the supervisor ------------------
    let bytes = scaled(16_384, 8_192);
    let (mut vsys, mut vpt) = Cheshire::default().virtual_system();
    let mut vsrc = vec![0u8; bytes as usize];
    XorShift64::new(0xD00D).fill(&mut vsrc);
    vsys.mems[0].data.write(SRC_PA, &vsrc);
    for off in (0..bytes.div_ceil(PAGE) * PAGE).step_by(PAGE as usize) {
        vpt.map(&mut vsys.mems[0].data, SRC_VA + off, SRC_PA + off);
    }
    // Destination pages intentionally unmapped: every first touch
    // faults; the handler maps the page and the supervisor replays.
    let vrec = shared(Recorder::new());
    let mut sup = Supervisor::new(vsys, RetryPolicy { max_attempts: 16, ..Default::default() })
        .with_fault_handler(move |va, sys| {
            let page = va & !(PAGE - 1);
            if !(DST_VA..DST_VA + bytes).contains(&page) {
                return false; // a real (unmappable) fault
            }
            vpt.map(&mut sys.mems[0].data, page, DST_PA + (page - DST_VA));
            true
        });
    sup.attach_sink(vrec.clone());
    let t = Transfer1D::copy(0, SRC_VA, DST_VA, bytes, ProtocolKind::Axi4);
    let r = sup.run_job(NdJob::new(1, NdTransfer::d1(t)));
    assert!(r.ok(), "demand paging must converge: {:?}", r.status);
    assert!(r.retries >= 1, "at least one fault-and-replay round");
    assert_eq!(
        sup.sys.mems[0].data.read_vec(DST_PA, bytes as usize),
        vsrc,
        "paged-in copy must be byte-identical"
    );
    let vs = vrec.borrow().summary();
    assert!(vs.page_faults >= 1, "the recorder must have seen the faults");
    println!(
        "\ndemand paging: {bytes} B copied after {} fault(s), {} replay round(s)",
        vs.page_faults, r.retries
    );

    let json = format!(
        concat!(
            "{{\"example\":\"gather_vm\",\"verify\":{},",
            "\"elements\":{},\"elem_bytes\":{},",
            "\"cold_cycles\":{},\"warm_cycles\":{},",
            "\"tlb_hits\":{},\"tlb_misses\":{},\"tlb_hit_rate\":{:.6},",
            "\"ptw_beats\":{},\"page_faults\":{},\"paging_retries\":{}}}"
        ),
        verify,
        p.count(),
        p.elem_len,
        cold_cycles,
        warm_cycles,
        s.tlb_hits,
        s.tlb_misses,
        s.tlb_hit_rate(),
        s.ptw_beats,
        vs.page_faults,
        r.retries
    );
    std::fs::write(&out, json + "\n").expect("write gather_vm report");
    println!("report: {out}");
}
