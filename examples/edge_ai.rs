//! **End-to-end driver** (§3.1 / DESIGN.md §7): MobileNetV1 inference on
//! the simulated PULP-open cluster. Every weight and activation tile is
//! physically moved between the simulated L2 and TCDM by the iDMA
//! engine (reg_32_3d → tensor_ND → AXI/OBI back-end), each layer's
//! numerics run on the AOT-compiled JAX/Pallas artifacts over PJRT, and
//! the final logits are verified against the Python-side expectation —
//! proving all three layers of the stack compose.
//!
//! Requires `make artifacts`. Run:
//! `cargo run --release --example edge_ai`

use idma::runtime::Runtime;
use idma::systems::pulp_open::{DmaKind, PulpOpen};

fn main() {
    let mut rt = Runtime::open_default()
        .expect("artifacts missing — run `make artifacts` first");
    let p = PulpOpen::default();

    println!("== tiny-MobileNetV1 inference through the simulated cluster ==");
    let r = p.mobilenet(DmaKind::Idma, Some(&mut rt));
    println!("DMA commands:        {}", r.commands);
    println!("DMA payload:         {} bytes", r.dma_bytes);
    println!("DMA busy cycles:     {}", r.dma_cycles);
    println!("cluster cycles:      {}", r.cycles);
    println!("logits:              {:?}", r.logits.as_ref().unwrap());
    println!(
        "verification:        {}",
        if r.verified { "PASS — logits match mb_expected.bin" } else { "FAIL" }
    );
    assert!(r.verified, "end-to-end numerics must match the Python model");

    println!("\n== paper-scale MAC/cycle (224x224 MobileNetV1, DORY model) ==");
    let full = p.mobilenet_paper_model(DmaKind::Idma);
    let mchan = p.mobilenet_paper_model(DmaKind::Mchan);
    println!("iDMA : {:.2} MAC/cycle (paper 8.3)", full.mac_per_cycle);
    println!("MCHAN: {:.2} MAC/cycle (paper 7.9)", mchan.mac_per_cycle);
    let (idma_ge, mchan_ge) = p.dmae_area();
    println!(
        "DMAE area: {:.0} vs {:.0} GE → {:.0}% smaller (paper 10%)",
        idma_ge,
        mchan_ge,
        (1.0 - idma_ge / mchan_ge) * 100.0
    );
}
