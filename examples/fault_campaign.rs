//! Deterministic fault-injection campaign across the paper's five §3
//! systems: for each (system, fault-scenario) pair a seeded, supervised
//! run exercises retry/backoff, partial-transfer replay, watchdog
//! timeouts and endpoint quarantine, then the aggregated outcomes are
//! written as a JSON report.
//!
//! The report is byte-deterministic for a given seed (verify with two
//! runs and `diff`). `IDMA_BENCH_SMOKE=1` shrinks the per-case job
//! count and deadline so CI finishes in seconds.
//!
//! Run: `cargo run --release --example fault_campaign [report.json]`

use idma::resilience::{run_campaign, CampaignCfg};
use idma::sim::bench::{scaled, smoke};

fn main() {
    let out = std::env::args().nth(1).unwrap_or_else(|| "fault_campaign.json".to_string());
    let cfg = CampaignCfg {
        jobs_per_case: scaled(4, 2),
        job_bytes: scaled(2048, 512),
        deadline: scaled(200_000, 50_000),
        ..Default::default()
    };
    println!(
        "fault-injection campaign: 5 systems x 5 scenarios, {} jobs/case, {} B/job, seed {:#x}{}",
        cfg.jobs_per_case,
        cfg.job_bytes,
        cfg.seed,
        if smoke() { " (smoke)" } else { "" }
    );

    let report = run_campaign(&cfg);
    println!(
        "\n{:<14} {:<16} {:>6} {:>10} {:>7} {:>9} {:>8}",
        "system", "scenario", "clean", "recovered", "failed", "timed_out", "retries"
    );
    for c in &report.cases {
        println!(
            "{:<14} {:<16} {:>6} {:>10} {:>7} {:>9} {:>8}",
            c.system, c.scenario, c.ok_clean, c.recovered, c.failed, c.timed_out, c.retries
        );
        assert_eq!(c.verify_failures, 0, "recovered data must be byte-identical ({c:?})");
    }

    let json = report.to_json();
    std::fs::write(&out, json + "\n").expect("write campaign report");
    println!("\nreport: {out}");
}
