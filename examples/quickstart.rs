//! Quickstart: build an iDMA engine with the §3.6 wrapper, move some
//! memory, initialize a buffer with the Init pseudo-protocol, and read
//! the area/timing/latency characterization for the configuration.
//!
//! Run: `cargo run --release --example quickstart`

use idma::backend::{BackendCfg, PortCfg};
use idma::engine::EngineBuilder;
use idma::mem::{Endpoint, MemModel};
use idma::midend::NdJob;
use idma::model::{synthesize_area, synthesize_fmax_ghz};
use idma::protocol::ProtocolKind;
use idma::system::IdmaSystem;
use idma::transfer::{InitPattern, NdTransfer, Transfer1D};

fn main() {
    // 1. An engine from the three §3.6 wrapper parameters:
    //    AW=32 bits, DW=8 bytes, NAx=8, with a 3D tensor mid-end —
    //    wrapped in the system facade with an SRAM-class endpoint
    //    (3 cycles, 8 outstanding).
    let engine = EngineBuilder::new(32, 8, 8).tensor(3).build().unwrap();
    let mut sys = IdmaSystem::new(engine, vec![Endpoint::new(MemModel::sram(8))]);
    let payload: Vec<u8> = (0..=255).collect();
    sys.mems[0].data.write(0x1000, &payload);

    // 2. A 2D transfer: 4 rows of 64 B, source stride 256 B.
    let inner = Transfer1D::copy(0, 0x1000, 0x8000, 64, ProtocolKind::Axi4);
    let nd = NdTransfer::d2(inner, 256, 64, 4);
    assert!(sys.submit(NdJob::new(1, nd)));

    // 3. A memory-init transfer right behind it (retry on back pressure).
    let init = Transfer1D::init(0, 0x9000, 128, InitPattern::Incrementing(0), ProtocolKind::Axi4);
    while !sys.submit(NdJob::new(2, NdTransfer::d1(init))) {
        sys.step();
    }

    // 4. Drain event-driven: the facade jumps over provably idle cycles.
    let end = sys.run_until_idle();
    for d in sys.take_done() {
        println!("job {} done at cycle {} (errors: {})", d.job, d.done, d.errors());
    }
    assert_eq!(sys.mems[0].data.read_vec(0x8000, 64), payload[0..64].to_vec());
    assert_eq!(sys.mems[0].data.read_u8(0x9000 + 77), 77);
    println!(
        "2D copy + memory init complete in {end} cycles ({} ticks executed) — byte exact.",
        sys.ticks()
    );

    // 5. Characterize the configuration (the §4 models).
    let cfg = BackendCfg {
        aw_bits: 32,
        dw_bytes: 8,
        nax_r: 8,
        nax_w: 8,
        ports: vec![PortCfg { protocol: ProtocolKind::Axi4, mem: 0 }],
        ..Default::default()
    };
    println!(
        "this back-end: {:.1} kGE, fmax {:.2} GHz, launch latency {} cycles",
        synthesize_area(&cfg).total() / 1000.0,
        synthesize_fmax_ghz(&cfg),
        idma::model::backend_latency(&cfg),
    );
}
