//! Serving-under-interference demo of the QoS subsystem: mixed traffic
//! on Cheshire — saturating best-effort bulk copies plus periodic
//! latency-critical 256 B jobs — run once through the strict in-order
//! baseline and once through the [`idma::qos::QosScheduler`] with
//! chunk-level preemption, followed by a 3:1 weighted-fairness split of
//! two same-priority classes. Writes a JSON report with the measured
//! p99 isolation ratio and the achieved bandwidth split.
//!
//! `IDMA_BENCH_SMOKE=1` shrinks both scenarios so CI finishes in
//! seconds.
//!
//! Run: `cargo run --release --example qos_serving [report.json]`

use idma::qos::scenario::{percentile_exact, FairnessScenario, IsolationScenario};
use idma::qos::{ClassConfig, QosPolicy, TrafficClass};
use idma::sim::bench::smoke;
use idma::systems::cheshire::Cheshire;

fn main() {
    let out = std::env::args().nth(1).unwrap_or_else(|| "qos_serving.json".to_string());
    let ch = Cheshire::default();

    // Isolation: high-priority 256 B jobs against saturating bulk.
    let sc = IsolationScenario::sized(smoke());
    println!(
        "isolation: {} x {} B bulk vs {} x {} B latency-critical{}",
        sc.bulk_jobs,
        sc.bulk_len,
        sc.hi_jobs,
        sc.hi_len,
        if smoke() { " (smoke)" } else { "" }
    );
    let mut base_sys = ch.resilient_system();
    let base = sc.run(&mut base_sys, None);
    let policy = QosPolicy::new(vec![
        ClassConfig::default(),
        ClassConfig { priority: 1, ..Default::default() },
    ])
    .with_chunk_bytes(2048);
    let mut qos_sys = ch.qos_system(policy);
    let qos = sc.run(&mut qos_sys, Some(TrafficClass(1)));
    let bp99 = percentile_exact(&base.hi_latencies, 99.0);
    let qp99 = percentile_exact(&qos.hi_latencies, 99.0);
    let ratio = bp99 as f64 / qp99.max(1) as f64;
    println!("  strict baseline p99: {bp99} cycles");
    println!("  QoS scheduler  p99 : {qp99} cycles  ({ratio:.1}x isolation)");

    // Weighted fairness: two same-priority classes, weights 3:1.
    let fpolicy = QosPolicy::new(vec![
        ClassConfig { weight: 3, ..Default::default() },
        ClassConfig { weight: 1, ..Default::default() },
    ])
    .with_chunk_bytes(2048);
    let mut fsys = ch.qos_system(fpolicy);
    let fout = FairnessScenario::sized(smoke()).run(&mut fsys);
    let target = 0.75;
    let measured = fout.share(0);
    let err = measured - target;
    println!("fairness: class 0 (weight 3) served {measured:.3} of in-window bytes (target {target:.2})");

    let verified = base.verified && qos.verified && fout.verified;
    let json = format!(
        concat!(
            "{{\"example\":\"qos_serving\",\"smoke\":{},",
            "\"baseline_p99_cycles\":{},\"qos_p99_cycles\":{},\"isolation_p99_ratio\":{:.3},",
            "\"weight_split_target\":{:.2},\"weight_split_measured\":{:.4},\"weight_split_error\":{:.4},",
            "\"all_completed\":{},\"verified\":{}}}"
        ),
        smoke(),
        bp99,
        qp99,
        ratio,
        target,
        measured,
        err,
        fout.all_completed,
        verified,
    );
    std::fs::write(&out, json + "\n").expect("write qos report");
    println!("\nreport: {out}");
}
