//! ControlPULP-style autonomous sensor acquisition (§3.2): the rt_3D
//! mid-end launches a repeated 3D readout of the PVT sensor map every
//! PVCT period with zero core involvement.
//!
//! Run: `cargo run --release --example realtime_sensors`

use idma::systems::control_pulp::ControlPulp;

fn main() {
    let c = ControlPulp::default();
    let r = c.run_hyperperiod();
    println!("one PFCT hyperperiod (500 µs at 500 MHz):");
    println!("  autonomous rt_3D launches: {}", r.launches);
    println!("  sensor data byte-exact:    {}", r.data_ok);
    println!("  core cycles, software:     {}", r.sw_core_cycles);
    println!("  core cycles, rt_3D:        {}", r.rt_core_cycles);
    println!("  saved per period:          {} (paper ≈2200)", r.saved);
    println!("  rt_3D area:                {:.0} GE (paper ≈11 kGE)", r.rt3d_area_ge);
    assert!(r.data_ok && r.launches == 10);
}
