//! The Init pseudo-protocol (§2.3, Table 3): hardware memory
//! initialization with constant, incrementing and pseudorandom
//! patterns, plus an in-stream-accelerator demo (block transpose).
//!
//! Run: `cargo run --release --example memory_init`

use idma::backend::{Backend, BackendCfg, BlockTranspose};
use idma::mem::{Endpoint, MemModel};
use idma::protocol::ProtocolKind;
use idma::systems::common::run_backend;
use idma::transfer::{InitPattern, Transfer1D};

fn run(be: &mut Backend, mems: &mut [Endpoint]) {
    run_backend(be, mems, 0, 100_000);
}

fn main() {
    let mut be = Backend::new(BackendCfg::default()).unwrap();
    let mut mems = [Endpoint::new(MemModel::sram(4))];
    for (i, (pattern, at)) in [
        (InitPattern::Constant(0xA5), 0x1000u64),
        (InitPattern::Incrementing(0), 0x2000),
        (InitPattern::Pseudorandom(42), 0x3000),
    ]
    .into_iter()
    .enumerate()
    {
        let t = Transfer1D::init(i as u64 + 1, at, 64, pattern, ProtocolKind::Axi4);
        assert!(be.try_submit(0, t));
        run(&mut be, &mut mems);
        println!("{pattern:?} @ {at:#x}: {:02x?}...", &mems[0].data.read_vec(at, 8));
    }

    // In-stream accelerator: transpose an 8×8 byte matrix during the copy.
    let mut be = Backend::new(BackendCfg::default()).unwrap();
    be.set_accel(Box::new(BlockTranspose { rows: 8, cols: 8, elem: 1 })).unwrap();
    let mut mems = [Endpoint::new(MemModel::sram(4))];
    let m: Vec<u8> = (0..64).collect();
    mems[0].data.write(0, &m);
    assert!(be.try_submit(0, Transfer1D::copy(9, 0, 0x100, 64, ProtocolKind::Axi4)));
    run(&mut be, &mut mems);
    let t = mems[0].data.read_vec(0x100, 64);
    assert_eq!(t[1], 8, "transposed");
    println!("block-transpose in flight: row 0 = {:?}", &t[..8]);
}
