//! Mixed control planes on one engine (paper §2.1 + Fig. 1): a
//! register-file front-end, a descriptor fetcher and an instruction
//! decoder — each programmed through its *native* surface — feed the
//! same back-end through the round-robin arbiter inside
//! [`idma::system::IdmaSystem`]. Completions route back to the
//! front-end that issued them, the whole run is event-driven, and a
//! telemetry [`Recorder`] traces every job's lifecycle into a Chrome
//! `trace_events` JSON (load it at `ui.perfetto.dev` or
//! `chrome://tracing`).
//!
//! Run: `cargo run --release --example mixed_frontends [trace.json]`

use idma::engine::EngineBuilder;
use idma::frontend::{
    decode, encode, regs, write_descriptor, DescFlags, DescFrontend, Frontend, InstFrontend,
    Opcode, RegFrontend, RegVariant,
};
use idma::mem::{Endpoint, MemModel};
use idma::protocol::ProtocolKind;
use idma::system::IdmaSystemBuilder;
use idma::telemetry::{shared, Recorder};

fn main() {
    // One engine (64-bit AXI4, 8 outstanding) behind three front-ends,
    // with a recorder observing the full submit→accept→beat→done path.
    let engine = EngineBuilder::new(32, 8, 8).build().unwrap();
    let rec = shared(Recorder::new());
    let mut sys = IdmaSystemBuilder::new(engine)
        .endpoint(Endpoint::new(MemModel::sram(8)))
        .frontend(Box::new(RegFrontend::new(RegVariant::R32, 0)))
        .frontend(Box::new(DescFrontend::new(6)))
        .frontend(Box::new(InstFrontend::new(0)))
        .sink(rec.clone())
        .build();
    let (reg, desc, inst) = (0usize, 1, 2);

    // Source payloads.
    for (base, fill) in [(0x1000u64, 0x11u8), (0x2000, 0x22), (0x3000, 0x33)] {
        sys.mems[0].data.write(base, &[fill; 512]);
    }

    // reg_32: memory-mapped register writes, launch via TRANSFER_ID read.
    let fe = sys.try_frontend_mut::<RegFrontend>(reg).unwrap();
    fe.write_reg(0, regs::SRC, 0x1000);
    fe.write_reg(0, regs::DST, 0x8000);
    fe.write_reg(0, regs::LEN, 512);
    let id = fe.read_reg(0, regs::TRANSFER_ID);
    println!("reg_32   launched transfer {id} with {} register ops", fe.reg_writes + 1);

    // desc_64: one descriptor in the control-plane SPM, single-write launch.
    write_descriptor(
        &mut sys.ctrl_mem,
        0x40,
        0,
        0x2000,
        0x9000,
        512,
        DescFlags::new(ProtocolKind::Axi4, ProtocolKind::Axi4),
    );
    assert!(sys.try_frontend_mut::<DescFrontend>(desc).unwrap().launch_chain(0, 0x40));
    println!("desc_64  launched a 1-descriptor chain with a single store");

    // inst_64: dmsrc / dmdst / dmcpy — three instructions.
    let fe = sys.try_frontend_mut::<InstFrontend>(inst).unwrap();
    fe.execute(0, decode(encode(Opcode::DmSrc, 0, 1, 2)).unwrap(), 0x3000, 0);
    fe.execute(1, decode(encode(Opcode::DmDst, 0, 1, 2)).unwrap(), 0xA000, 0);
    let id = fe.execute(2, decode(encode(Opcode::DmCpy, 5, 1, 2)).unwrap(), 512, 0).unwrap();
    println!("inst_64  launched transfer {id} in three instructions");

    // Event-driven drain through the arbiter; completions fan back.
    let end = sys.run_until_idle();
    println!("\nall three jobs retired by cycle {end} ({} ticks executed):", sys.ticks());
    for d in sys.take_done() {
        let fe = d.frontend.expect("front-end jobs carry their source");
        println!(
            "  front-end {fe} ({}) job {}: submitted {} accepted {} first beat {:?} done {}",
            sys.frontend_dyn(fe).name(),
            d.job,
            d.submitted,
            d.accepted,
            d.first_beat,
            d.done,
        );
    }
    for (i, dst, fill) in [(reg, 0x8000u64, 0x11u8), (desc, 0x9000, 0x22), (inst, 0xA000, 0x33)] {
        assert_eq!(sys.frontend_dyn(i).status(), 1, "front-end {i} completion observed");
        assert_eq!(sys.mems[0].data.read_vec(dst, 512), vec![fill; 512]);
    }
    println!("byte-exact on all three destinations — mixed control planes compose.");

    // Export the recorded lifecycle as a Chrome trace.
    let rec = rec.borrow();
    let s = rec.summary();
    println!(
        "telemetry: {} jobs, {} B read, {} B written over {} cycles",
        s.jobs,
        s.bytes_read,
        s.bytes_written,
        s.cycles()
    );
    let path = std::env::args().nth(1).unwrap_or_else(|| "trace_mixed_frontends.json".into());
    match rec.write_chrome_trace(&path) {
        Ok(()) => println!("chrome trace written to {path} — open in ui.perfetto.dev"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
